"""The schedule-compiler fast path's equivalence gate.

``QueueHarness.run_batched`` now replays compiled steady-state op
schedules (:mod:`repro.core.opsched`) instead of executing every primitive
per op.  The acceptance criterion is *bit identity*: for all 8 queues x 3
memory models x contention off/on/learned, the compiled fast path must
produce exactly the per-thread Stats (every counter AND the float
``time_ns``), the same linearization events, the same op records and the
same final queue contents as per-op ClockScheduler execution
(``compiled=False``).  Both executor backends -- the generated-code one
and the instruction interpreter -- are held to the same standard.
"""
import pytest

from repro.core import (ALL_QUEUES, MEMORY_MODELS, FastPathExecutor,
                        QueueHarness, linearizing_root,
                        retry_touches_persistent)
from benchmarks.workloads import make_plans, resolve_contention

QUEUES8 = sorted(ALL_QUEUES)
CONTENTION = ["off", "on", "learned"]


def _run(qname, compiled, model, contention="off", workload="mixed5050",
         nthreads=3, ops=40, area_nodes=256, prefill=None, seed=0,
         backend="codegen"):
    h = QueueHarness(ALL_QUEUES[qname], nthreads=nthreads,
                     area_nodes=area_nodes, model=model)
    plans, wl_prefill = make_plans(workload, nthreads, ops, seed=seed)
    for i in range(wl_prefill if prefill is None else prefill):
        h.queue.enqueue(0, ("pre", i))
    _, cmodel = resolve_contention(contention, qname)
    if compiled and backend != "codegen":
        # route the harness through the interpreter backend
        orig = h._make_fast_executor

        def _interp():
            ex = orig()
            return None if ex is None else FastPathExecutor(
                h.queue, h.nvram, record=ex.record, backend="interp")
        h._make_fast_executor = _interp
    res = h.run_batched(plans, compiled=compiled, contention=cmodel)
    return h, res


def assert_bit_identical(qname, model, contention, **kw):
    h_ref, r_ref = _run(qname, False, model, contention, **kw)
    h_fast, r_fast = _run(qname, True, model, contention, **kw)
    s_ref, s_fast = h_ref.nvram.stats, h_fast.nvram.stats
    for t in s_ref:
        assert s_ref[t] == s_fast[t], (
            f"{qname}/{model}/{contention}: thread {t} Stats diverge\n"
            f"  per-op: {s_ref[t]}\n  fast:   {s_fast[t]}")
    assert r_ref.events == r_fast.events
    assert r_ref.ops == r_fast.ops
    assert r_ref.sim_time_ns == r_fast.sim_time_ns
    # final logical queue contents must agree too
    assert h_ref.queue.drain(0) == h_fast.queue.drain(0)
    return h_fast


@pytest.mark.parametrize("model", sorted(MEMORY_MODELS))
@pytest.mark.parametrize("qname", QUEUES8)
def test_fastpath_bit_identical_all_models(qname, model):
    """The core gate: 8 queues x 3 models, mixed workload, contention off."""
    h = assert_bit_identical(qname, model, "off")
    assert h.fast is not None and h.fast.fast_ops > 0, \
        "fast path never engaged -- the equivalence test lost its subject"


@pytest.mark.parametrize("contention", ["on", "learned"])
@pytest.mark.parametrize("qname", QUEUES8)
def test_fastpath_bit_identical_contended(qname, contention):
    """Contended runs: the compiled replay must feed the ContentionModel
    the same CAS tags, line epochs and clocks as per-op execution."""
    assert_bit_identical(qname, "optane-clwb", contention)


@pytest.mark.parametrize("qname", ["DurableMSQ", "UnlinkedQ", "OptLinkedQ"])
def test_fastpath_bit_identical_interpreter_backend(qname):
    """The instruction-interpreting backend executes the identical opcode
    program; hold it to the same bit-identity bar as the codegen one."""
    h_ref, r_ref = _run(qname, False, "optane-clwb")
    h_int, r_int = _run(qname, True, "optane-clwb", backend="interp")
    s_ref, s_int = h_ref.nvram.stats, h_int.nvram.stats
    for t in s_ref:
        assert s_ref[t] == s_int[t]
    assert r_ref.events == r_int.events and r_ref.ops == r_int.ops


@pytest.mark.parametrize("qname", QUEUES8)
def test_fastpath_pairs_and_bursts(qname):
    """Different op mixes reach different steady states; pairs and
    producer bursts must replay bit-identically too."""
    assert_bit_identical(qname, "optane-clwb", "off", workload="pairs")
    assert_bit_identical(qname, "optane-clwb", "off", workload="producers")


def test_fastpath_mostly_fast_in_steady_state():
    """Sanity on coverage: in a warm mixed run the overwhelming majority
    of ops must take the compiled path, not the bail path."""
    h, _ = _run("DurableMSQ", True, "optane-clwb", ops=200, nthreads=4)
    total = h.fast.fast_ops + h.fast.bailed_ops
    assert h.fast.fast_ops / total > 0.85, (h.fast.fast_ops, total)


def test_second_amendment_zero_post_flush_on_fast_path():
    """The paper's headline invariant survives compilation: OptUnlinkedQ /
    OptLinkedQ runs stay at zero post-flush accesses on the fast path."""
    for qname in ("OptUnlinkedQ", "OptLinkedQ"):
        h, res = _run(qname, True, "optane-clwb", ops=120, nthreads=4)
        assert res.stats.post_flush_accesses == 0


def test_schedule_derived_roots_match_declared_profiles():
    """Tentpole wiring: retry_profile() roots come from the op_schedule's
    root CAS, and volatile-only retry bodies are detected so contended
    profiles cannot claim flushed re-reads the schedule forbids."""
    for qname, cls in ALL_QUEUES.items():
        h = QueueHarness(cls, nthreads=2, area_nodes=64)
        q = h.queue
        scheds = q.op_schedule()
        assert scheds is not None, f"{qname} lost its op_schedule"
        profiles = q.retry_profile()
        facts = q.schedule_facts()
        for kind in ("enq", "deq"):
            root = linearizing_root(q, scheds.of_kind(kind))
            assert profiles[kind].root == root
            assert facts[kind]["root"] == root
        flushable = {k: retry_touches_persistent(q, scheds.of_kind(k))
                     for k in ("enq", "deq")}
        if qname in ("MSQ", "OptUnlinkedQ", "OptLinkedQ"):
            assert not any(flushable.values()), (
                f"{qname}: a volatile-only retry body was classified as "
                f"able to touch flushed content: {flushable}")
        else:
            assert any(flushable.values())
