"""Unit tests for the pluggable memory models: flush/fence/read cost and
behaviour semantics of optane-clwb, eadr and cxl on both engines."""
import pytest

from repro.core import (ALL_QUEUES, CXL_MEM, EADR, MEMORY_MODELS, NVRAM,
                        OPTANE_CLWB, QueueHarness, ReferenceNVRAM,
                        get_memory_model)

ENGINES = [NVRAM, ReferenceNVRAM]


def test_registry_and_lookup():
    assert set(MEMORY_MODELS) == {"optane-clwb", "eadr", "cxl"}
    assert get_memory_model("eadr") is EADR
    assert get_memory_model(None) is OPTANE_CLWB
    assert get_memory_model(CXL_MEM) is CXL_MEM
    with pytest.raises(ValueError):
        get_memory_model("nvdimm-9000")


def test_model_flags():
    assert OPTANE_CLWB.flush_invalidates and OPTANE_CLWB.needs_flush
    assert not OPTANE_CLWB.persist_on_store
    assert EADR.persist_on_store and not EADR.needs_flush
    assert not EADR.flush_invalidates and EADR.flush_issue_ns == 0.0
    assert CXL_MEM.needs_flush and not CXL_MEM.flush_invalidates
    assert CXL_MEM.nvram_read_ns > OPTANE_CLWB.nvram_read_ns


# ------------------------------------------------------------ flush semantics
@pytest.mark.parametrize("engine", ENGINES)
def test_optane_flush_invalidates_next_read_pays_nvram(engine):
    nv = engine(1, model="optane-clwb")
    a = nv.alloc_region(8, "r")
    nv.write(a, 1)
    nv.flush(a)
    nv.fence()
    t0 = nv.total_stats().time_ns
    nv.read(a)
    assert nv.total_stats().post_flush_accesses == 1
    assert nv.total_stats().time_ns - t0 >= OPTANE_CLWB.nvram_read_ns


@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("model", ["eadr", "cxl"])
def test_non_invalidating_flush_keeps_line_cached(engine, model):
    """eADR and CXL flushes leave the line in cache: the re-read is a hit
    and the post-flush counter stays at zero."""
    nv = engine(1, model=model)
    a = nv.alloc_region(8, "r")
    nv.write(a, 1)
    nv.flush(a)
    nv.fence()
    t0 = nv.total_stats().time_ns
    assert nv.read(a) == 1
    m = get_memory_model(model)
    assert nv.total_stats().post_flush_accesses == 0
    assert nv.total_stats().time_ns - t0 == pytest.approx(m.cache_hit_ns)


# ------------------------------------------------------------ fence/read cost
@pytest.mark.parametrize("engine", ENGINES)
def test_fence_cost_scales_with_model(engine):
    """Same instruction sequence, different drain cost per model."""
    def fence_cost(model):
        nv = engine(1, model=model)
        a = nv.alloc_region(8, "r")
        nv.write(a, 1)
        nv.flush(a)
        t0 = nv.total_stats().time_ns
        nv.fence()
        return nv.total_stats().time_ns - t0

    assert fence_cost("cxl") > fence_cost("optane-clwb") > fence_cost("eadr")
    m = get_memory_model("eadr")
    assert fence_cost("eadr") == pytest.approx(m.fence_base_ns)


@pytest.mark.parametrize("engine", ENGINES)
def test_cold_read_cost_differs_by_model(engine):
    def cold_read_cost(model):
        nv = engine(1, model=model)
        a = nv.alloc_region(8, "r")
        nv.write(a, 1)
        nv.flush(a)
        nv.fence()
        nv.read(a)       # re-cache (post-flush under optane)
        nv.flush(a)      # invalidate again under optane only
        nv.fence()
        t0 = nv.total_stats().time_ns
        nv.read(a)
        return nv.total_stats().time_ns - t0

    assert cold_read_cost("optane-clwb") == pytest.approx(
        OPTANE_CLWB.nvram_read_ns)
    # no invalidation => both are plain cache hits
    assert cold_read_cost("cxl") == pytest.approx(CXL_MEM.cache_hit_ns)
    assert cold_read_cost("eadr") == pytest.approx(EADR.cache_hit_ns)


# ------------------------------------------------------- durability semantics
@pytest.mark.parametrize("engine", ENGINES)
def test_eadr_store_is_durable_without_flush_or_fence(engine):
    """persist-on-store: a visible store survives even an adversarial
    ('min') crash with no flush and no fence issued."""
    nv = engine(1, model="eadr")
    a = nv.alloc_region(8, "r")
    nv.write(a, 42)
    nv.crash(mode="min")
    assert nv.pread(a) == 42


@pytest.mark.parametrize("engine", ENGINES)
def test_flush_based_models_lose_unflushed_stores(engine):
    for model in ("optane-clwb", "cxl"):
        nv = engine(1, model=model)
        a = nv.alloc_region(8, "r")
        nv.write(a, 42)
        nv.crash(mode="min")
        assert nv.pread(a) is None, model


# --------------------------------------------------------- queue-level effect
def test_eadr_elides_queue_flushes_entirely():
    """The model-aware persist helpers skip CLWB on eADR: a full queue run
    issues zero flushes (and still zero post-flush accesses)."""
    h = QueueHarness(ALL_QUEUES["DurableMSQ"], nthreads=1, area_nodes=128,
                     model="eadr")
    base = h.nvram.total_stats()
    for i in range(40):
        h.queue.enqueue(0, i)
    for i in range(40):
        assert h.queue.dequeue(0) == i
    d = h.nvram.total_stats().minus(base)
    assert d.flushes == 0
    assert d.post_flush_accesses == 0
    assert d.fences > 0          # ordering barriers remain


def test_model_changes_simulated_cost_ordering():
    """eADR must be the cheapest platform and the post-flush-heavy queues
    must benefit the most from leaving optane-clwb."""
    def cost(name, model):
        h = QueueHarness(ALL_QUEUES[name], nthreads=1, area_nodes=128,
                         model=model)
        base = h.nvram.total_stats()
        for i in range(40):
            h.queue.enqueue(0, i)
        for i in range(40):
            h.queue.dequeue(0)
        return h.nvram.total_stats().minus(base).time_ns

    for name in ("DurableMSQ", "OptUnlinkedQ"):
        assert cost(name, "eadr") < cost(name, "optane-clwb")
    # the 2nd amendment's whole advantage is removing post-flush accesses;
    # on a platform without the penalty the baseline catches back up
    gap_optane = cost("DurableMSQ", "optane-clwb") \
        - cost("OptUnlinkedQ", "optane-clwb")
    gap_eadr = cost("DurableMSQ", "eadr") - cost("OptUnlinkedQ", "eadr")
    assert gap_eadr < gap_optane


def test_crash_recovery_works_under_all_models():
    """Recovery correctness is model-independent: enqueue, crash, recover,
    drain on every model x a flush-based and an NT-store-based queue."""
    for model in sorted(MEMORY_MODELS):
        for name in ("DurableMSQ", "OptLinkedQ"):
            h = QueueHarness(ALL_QUEUES[name], nthreads=1, area_nodes=128,
                             model=model)
            for i in range(10):
                h.queue.enqueue(0, i)
            h.crash_and_recover(mode="max", seed=1)
            assert h.queue.drain(0) == list(range(10)), (name, model)
