"""The observability layer's non-interference gate.

``repro.obs`` is observation-only: attaching a :class:`PhaseProfiler` to
``run_batched`` (which threads it through ClockScheduler's heap loop, the
columnar record store's staged sync and the bail path) or a profiler +
:class:`Heartbeat` to ``run_fleet`` must leave every per-thread Stats
counter, linearization event, op record and simulated clock *bit
identical* to the untelemetered run -- the same contract the PR-3 trace
tap and the columnar engine are held to (`tests/test_fastpath_equivalence.py`).
"""
import io

import pytest

from repro.core import ALL_QUEUES, MEMORY_MODELS, QueueHarness
from repro.fleet import FleetConfig, run_fleet
from repro.obs import Heartbeat, PhaseProfiler
from benchmarks.workloads import make_plans

QUEUES8 = sorted(ALL_QUEUES)


def _run(qname, model, profile=None, nthreads=3, ops=30, seed=0):
    h = QueueHarness(ALL_QUEUES[qname], nthreads=nthreads,
                     area_nodes=256, model=model)
    plans, prefill = make_plans("mixed5050", nthreads, ops, seed=seed)
    for i in range(prefill):
        h.queue.enqueue(0, ("pre", i))
    res = h.run_batched(plans, profile=profile)
    return h, res


@pytest.mark.parametrize("model", sorted(MEMORY_MODELS))
@pytest.mark.parametrize("qname", QUEUES8)
def test_profiled_run_bit_identical(qname, model):
    """8 queues x 3 models: profiler on vs off, everything identical."""
    h_ref, r_ref = _run(qname, model)
    prof = PhaseProfiler()
    h_obs, r_obs = _run(qname, model, profile=prof)
    s_ref, s_obs = h_ref.nvram.stats, h_obs.nvram.stats
    for t in s_ref:
        assert s_ref[t] == s_obs[t], (
            f"{qname}/{model}: thread {t} Stats diverge under profiling\n"
            f"  off: {s_ref[t]}\n  on:  {s_obs[t]}")
    assert r_ref.events == r_obs.events
    assert r_ref.ops == r_obs.ops
    assert r_ref.sim_time_ns == r_obs.sim_time_ns
    assert h_ref.queue.drain(0) == h_obs.queue.drain(0)
    # and the profiler actually observed the run
    assert len(r_obs.ops) > 0 and prof.total_ns() > 0
    assert "bookkeeping" in prof.totals


def test_profiled_run_covers_wall_and_names_exec_phases():
    """The profiled columnar run attributes time to the documented phases
    and the phase sum accounts for (essentially all of) the wall clock
    of ``run_batched`` -- the region the profiler instruments (harness
    construction and the per-primitive prefill are outside it)."""
    import time
    h = QueueHarness(ALL_QUEUES["DurableMSQ"], nthreads=4,
                     area_nodes=256, model="optane-clwb")
    plans, prefill = make_plans("mixed5050", 4, 200, seed=0)
    for i in range(prefill):
        h.queue.enqueue(0, ("pre", i))
    prof = PhaseProfiler()
    t0 = time.perf_counter()
    h.run_batched(plans, profile=prof)
    wall = time.perf_counter() - t0
    assert {"heap-loop", "interpreted-body", "bookkeeping"} <= set(prof.totals)
    per = prof.us_per_op(800)
    assert all(v >= 0 for v in per.values())
    # push/pop hand off at a shared timestamp, so covered time can only be
    # lost outside run_batched -- coverage must sit tight under 1.0
    assert 0.9 <= prof.coverage(wall) <= 1.01, (prof.coverage(wall), wall)


def test_profiler_detached_after_run():
    """run_batched must not leave the profiler hooked into the record
    store once it returns (a later unprofiled run would be polluted)."""
    prof = PhaseProfiler()
    h, _ = _run("DurableMSQ", "optane-clwb", profile=prof)
    assert h._rstore is None or h._rstore.profiler is None
    assert prof._stack == []  # every push matched by a pop


def _fleet_cfg():
    return FleetConfig(queue="DurableMSQ", instances=400, ops=24,
                       chunk=12, backend="numpy", seed=7)


def test_fleet_telemetry_bit_identical_and_heartbeat_emits():
    """Fleet cell: profiler + heartbeat on vs off -- identical counts,
    bails and residents; heartbeat lines land on the given stream."""
    ref = run_fleet(_fleet_cfg())
    prof = PhaseProfiler()
    stream = io.StringIO()
    hb = Heartbeat(interval_s=0.0, stream=stream, label="fleet-test")
    obs = run_fleet(_fleet_cfg(), profile=prof, heartbeat=hb)
    assert (ref.counts == obs.counts).all()
    assert ref.bails == obs.bails and ref.residents == obs.residents
    assert {"lowering", "chunk-step"} <= set(prof.totals)
    lines = stream.getvalue().splitlines()
    assert lines and lines[-1].startswith("# fleet-test-done:")
    assert any("-heartbeat:" in ln for ln in lines[:-1])
    assert "100.0%" in lines[-1]


def test_fleet_quiet_without_heartbeat():
    """No heartbeat object -> nothing written anywhere (the --quiet /
    test-suite default)."""
    res = run_fleet(_fleet_cfg())
    assert res.counts.shape[0] == 400
