"""Exactly-once data delivery + durable serving + train crash-restart +
elastic/compression units."""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.data import DurableShardQueue
from repro.serving import DurableRequestQueue, ServeEngine
from repro.configs import reduced_config
from repro.launch.elastic import StragglerPolicy, factorize_mesh, plan_remesh


def test_shard_queue_order_and_recovery(tmp_path):
    q = DurableShardQueue(str(tmp_path))
    q.enqueue_shards([{"shard": i} for i in range(10)])
    seen = []
    for _ in range(4):
        s = q.next_shard()
        seen.append(s["shard"])
    # commit only the first three
    q.commit_consumed(2)
    q.close()
    # crash: new process view
    q2 = DurableShardQueue(str(tmp_path))
    resume = q2.recover()
    assert resume == 3
    nxt = q2.next_shard()
    assert nxt["shard"] == 3, "uncommitted shard must be re-delivered"
    q2.close()


def test_exactly_once_across_crash(tmp_path):
    """Effective (committed) consumption history has no gaps and no repeats
    across a crash."""
    q = DurableShardQueue(str(tmp_path))
    q.enqueue_shards([{"shard": i} for i in range(8)])
    committed = []
    for i in range(5):
        s = q.next_shard()
        if i < 3:                       # only 3 consumptions get committed
            q.commit_consumed(s["_queue_index"])
            committed.append(s["shard"])
    q.close()                           # crash after
    q2 = DurableShardQueue(str(tmp_path))
    q2.recover()
    while True:
        s = q2.next_shard()
        if s is None:
            break
        q2.commit_consumed(s["_queue_index"])
        committed.append(s["shard"])
    assert committed == list(range(8))   # exactly once, in order
    q2.close()


def test_serving_durable_roundtrip(tmp_path):
    cfg = reduced_config("musicgen-medium")
    # musicgen is embed_stub for train, but serving uses token ids; use a
    # token arch instead for the engine test:
    cfg = reduced_config("yi-6b")
    q = DurableRequestQueue(str(tmp_path))
    reqs = [{"id": f"r{i}", "prompt": [1 + i, 2, 3]} for i in range(6)]
    q.submit(reqs)
    eng = ServeEngine(cfg, q, max_len=32)
    n = eng.run(batch_size=4, max_new=4)
    assert n == 6
    resps = q.responses()
    assert sorted(r["id"] for r in resps) == sorted(r["id"] for r in reqs)
    assert all(len(r["tokens"]) == 4 for r in resps)
    q.close()


def test_serving_crash_replays_pending(tmp_path):
    cfg = reduced_config("yi-6b")
    q = DurableRequestQueue(str(tmp_path))
    q.submit([{"id": f"r{i}", "prompt": [i + 1, 5]} for i in range(6)])
    eng = ServeEngine(cfg, q, max_len=32)
    eng.serve_once(batch_size=2, max_new=2)      # 2 responded
    q.close()                                    # crash
    q2 = DurableRequestQueue(str(tmp_path))
    pending = q2.recover()
    assert pending == 4
    eng2 = ServeEngine(cfg, q2, max_len=32)
    eng2.run(batch_size=4, max_new=2)
    assert len(q2.responses()) == 6
    ids = [r["id"] for r in q2.responses()]
    assert len(set(ids)) == 6
    q2.close()


@pytest.mark.slow
def test_train_crash_restart_end_to_end(tmp_path):
    """Real abrupt-exit crash + restart through the driver subprocess."""
    env = dict(os.environ, PYTHONPATH="src")
    args = [sys.executable, "-m", "repro.launch.train", "--arch", "yi-6b",
            "--steps", "12", "--ckpt-every", "4",
            "--ckpt-dir", str(tmp_path), "--batch", "2", "--seq-len", "32"]
    p1 = subprocess.run(args + ["--crash-at", "6"], env=env,
                        capture_output=True, text=True, cwd="/root/repo")
    assert p1.returncode == 42, p1.stderr[-2000:]
    assert "checkpointed" in p1.stdout
    p2 = subprocess.run(args, env=env, capture_output=True, text=True,
                        cwd="/root/repo")
    assert p2.returncode == 0, p2.stderr[-2000:]
    assert "[recovery] resumed from step 4" in p2.stdout
    assert "done: 12 steps" in p2.stdout


# ----------------------------------------------------------- elastic planning
def test_factorize_mesh():
    assert factorize_mesh(512, 16) == (2, 16, 16)
    assert factorize_mesh(256, 16) == (1, 16, 16)
    assert factorize_mesh(100, 16) is None


def test_plan_remesh_shrinks_data_axis():
    plan = plan_remesh(n_healthy=120, old=(2, 16, 16), chips_per_host=4)
    pods, data, model = plan.new_mesh
    assert model == 16                   # TP pinned
    assert pods * data * model <= 480
    assert plan.restart_from_checkpoint
    assert any("optimizer" in m for m in plan.moves)


def test_straggler_policy():
    pol = StragglerPolicy(deadline_ms=100, min_participation=0.75)
    out = pol.step_outcome([10, 20, 50, 300])
    assert out["action"] == "proceed"
    assert abs(out["grad_scale"] - 4 / 3) < 1e-6
    out2 = pol.step_outcome([10, 300, 300, 300])
    assert out2["action"] == "wait_full"
    misses = {}
    for _ in range(3):
        evict = pol.track_misses(misses, {"h0": 10, "h1": 500})
    assert evict == ["h1"]


# ------------------------------------------------------- gradient compression
def test_grad_compression_error_feedback():
    import jax.numpy as jnp
    from repro.distributed.collectives import (compress_grads,
                                               compressed_bytes,
                                               decompress_grads,
                                               init_error_feedback)
    rng = np.random.RandomState(0)
    grads = {"a": jnp.asarray(rng.randn(64, 64), jnp.float32),
             "b": jnp.asarray(rng.randn(256), jnp.float32)}
    err = init_error_feedback(grads)
    # accumulated bf16-compressed grads with error feedback converge to the
    # true running sum (the EF guarantee)
    total_true = {k: np.zeros(v.shape, np.float32) for k, v in grads.items()}
    total_comp = {k: np.zeros(v.shape, np.float32) for k, v in grads.items()}
    for step in range(30):
        c, err = compress_grads(grads, err, method="bf16")
        d = decompress_grads(c)
        for k in grads:
            total_true[k] += np.asarray(grads[k])
            total_comp[k] += np.asarray(d[k])
    for k in grads:
        err_now = np.abs(total_comp[k] - total_true[k]).max()
        assert err_now < 0.05, f"error feedback diverged: {err_now}"
    # wire size halves
    c, _ = compress_grads(grads, init_error_feedback(grads), "bf16")
    assert compressed_bytes(c) * 2 == sum(
        v.size * 4 for v in grads.values())


def test_grad_compression_int8():
    import jax.numpy as jnp
    from repro.distributed.collectives import (compress_grads,
                                               decompress_grads,
                                               init_error_feedback)
    rng = np.random.RandomState(1)
    grads = {"w": jnp.asarray(rng.randn(128, 32), jnp.float32)}
    c, err = compress_grads(grads, init_error_feedback(grads), "int8")
    d = decompress_grads(c)
    rel = np.abs(np.asarray(d["w"]) - np.asarray(grads["w"])).max() \
        / np.abs(np.asarray(grads["w"])).max()
    assert rel < 0.02
