"""Properties of the contention layer: it must be exactly inert when there
is nothing to contend with.

The layer lives ABOVE the engine's cost accumulator -- it only appends
extra event codes.  Therefore:

* one thread (no co-scheduled ops => k == 0), or
* a zero CAS-failure probability (``retry_scale=0``)

must reproduce the uncontended batched counts **bit-identically** (every
Stats field including time_ns), for all seven durable queues on all three
memory models.  This is also what keeps ``tests/test_engine_differential.py``
untouched: single-thread cost semantics cannot drift.

The second amendment's headline invariant survives contention: modeled
retries for OptUnlinkedQ/OptLinkedQ re-read volatile halves only, so
post_flush_accesses stays exactly zero in contended multi-thread runs.
"""
import pytest

from repro.core import (ALL_QUEUES, MEMORY_MODELS, ContentionModel,
                        QueueHarness)
from benchmarks.workloads import make_plans

DURABLE7 = ["DurableMSQ", "IzraelevitzQ", "NVTraverseQ", "UnlinkedQ",
            "LinkedQ", "OptUnlinkedQ", "OptLinkedQ"]
STAT_FIELDS = ["reads", "writes", "cas", "flushes", "fences", "movntis",
               "post_flush_accesses", "cold_misses", "time_ns"]


def _run(name, model, nthreads, contention, ops=40):
    h = QueueHarness(ALL_QUEUES[name], nthreads=nthreads, area_nodes=512,
                     model=model)
    plans, prefill = make_plans("pairs", nthreads, ops)
    for i in range(prefill):
        h.queue.enqueue(0, ("pre", i))
    res = h.run_batched(plans, contention=contention)
    assert res.ops_completed == nthreads * ops
    return h.nvram.total_stats()


@pytest.mark.parametrize("model", sorted(MEMORY_MODELS))
@pytest.mark.parametrize("name", DURABLE7)
def test_one_thread_contention_is_bit_identical(name, model):
    plain = _run(name, model, 1, contention=None)
    contended = _run(name, model, 1, contention=True)
    for f in STAT_FIELDS:
        assert getattr(contended, f) == getattr(plain, f), (
            f"{name}/{model}: 1-thread contention perturbed {f}: "
            f"{getattr(contended, f)} != {getattr(plain, f)}")


@pytest.mark.parametrize("model", sorted(MEMORY_MODELS))
@pytest.mark.parametrize("name", DURABLE7)
def test_zero_failure_probability_is_bit_identical(name, model):
    plain = _run(name, model, 4, contention=None)
    contended = _run(name, model, 4,
                     contention=ContentionModel(retry_scale=0.0))
    for f in STAT_FIELDS:
        assert getattr(contended, f) == getattr(plain, f), (
            f"{name}/{model}: retry_scale=0 perturbed {f}: "
            f"{getattr(contended, f)} != {getattr(plain, f)}")


@pytest.mark.parametrize("model", sorted(MEMORY_MODELS))
@pytest.mark.parametrize("name", ["OptUnlinkedQ", "OptLinkedQ"])
def test_second_amendment_zero_post_flush_under_contention(name, model):
    stats = _run(name, model, 8, contention=True)
    assert stats.post_flush_accesses == 0


def test_contended_run_actually_charges():
    """Guard against the inertness tests passing vacuously: at 8 threads the
    default model must charge a nonzero retry load."""
    h = QueueHarness(ALL_QUEUES["UnlinkedQ"], nthreads=8, area_nodes=512)
    plans, prefill = make_plans("pairs", 8, 40)
    for i in range(prefill):
        h.queue.enqueue(0, ("pre", i))
    h.run_batched(plans, contention=True)
    assert h.contention.retries_charged > 0


def test_engine_bookkeeping_gated_on_tracking():
    """CAS-target tags and line access epochs are stamped while a model is
    attached (and readable afterwards), but uncontended runs on the same
    engine pay nothing: the harness drops the tracking flag at run end."""
    h = QueueHarness(ALL_QUEUES["UnlinkedQ"], nthreads=4, area_nodes=512)
    plans, prefill = make_plans("pairs", 4, 20)
    for i in range(prefill):
        h.queue.enqueue(0, ("pre", i))
    nv = h.queue.nvram
    assert not nv.contention_tracking and nv.cas_targets() == {}
    h.run_batched(plans, contention=True)
    assert not nv.contention_tracking          # reset for later runs
    root = h.queue.HEAD
    assert nv.cas_count(root) > 0              # dequeues tagged the head
    assert nv.line_epoch(root // 8) > 0        # its line epoch was stamped
    # a follow-up uncontended run must not grow the bookkeeping
    tags_before = sum(nv.cas_targets().values())
    h2_plans, _ = make_plans("pairs", 4, 10)
    h.run_batched(h2_plans)
    assert sum(nv.cas_targets().values()) == tags_before


def test_contention_rejects_reference_engine():
    """The differential oracle stays contention-free by design."""
    from repro.core import ReferenceNVRAM
    h = QueueHarness(ALL_QUEUES["UnlinkedQ"], nthreads=2, area_nodes=256,
                     nvram_cls=ReferenceNVRAM)
    plans, _ = make_plans("pairs", 2, 4)
    with pytest.raises(TypeError):
        h.run_batched(plans, contention=True)
