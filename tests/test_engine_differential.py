"""Differential tests: the batched array engine vs the sequential reference.

The batched engine (repro.core.nvram.NVRAM) must reproduce the reference
dict engine's (repro.core.nvram_ref.ReferenceNVRAM) per-op persist
accounting EXACTLY -- same fences, flushes, post-flush accesses, reads,
writes, CAS count, cold misses and simulated time -- for every queue, on
every memory model.  The reference engine is the seed implementation kept
frozen as an oracle; any accounting drift in the fast path is a bug.
"""
import time

import pytest

from repro.core import (ALL_QUEUES, MEMORY_MODELS, NVRAM, QueueHarness,
                        ReferenceNVRAM)
from benchmarks.workloads import make_plans

DURABLE7 = ["DurableMSQ", "IzraelevitzQ", "NVTraverseQ", "UnlinkedQ",
            "LinkedQ", "OptUnlinkedQ", "OptLinkedQ"]
STAT_FIELDS = ["reads", "writes", "cas", "flushes", "fences", "movntis",
               "post_flush_accesses", "cold_misses", "time_ns"]


def _run_sequential(name, model, nvram_cls=None, n_ops=100):
    kwargs = {} if nvram_cls is None else {"nvram_cls": nvram_cls}
    h = QueueHarness(ALL_QUEUES[name], nthreads=1, area_nodes=256,
                     model=model, **kwargs)
    plan, _prefill = make_plans("pairs", 1, n_ops)
    base = h.nvram.total_stats()
    res = h.run_single(plan[0])
    return res, h.nvram.total_stats().minus(base)


@pytest.mark.parametrize("name", DURABLE7)
def test_batched_matches_reference_pairs(name):
    """The acceptance criterion: per-op persist accounting matches exactly
    for all seven queues on the `pairs` workload."""
    res_b, d_b = _run_sequential(name, "optane-clwb")
    res_r, d_r = _run_sequential(name, "optane-clwb",
                                 nvram_cls=ReferenceNVRAM)
    assert res_b.ops_completed == res_r.ops_completed
    ops = res_b.ops_completed
    assert d_b.fences / ops == d_r.fences / ops
    assert d_b.post_flush_accesses / ops == d_r.post_flush_accesses / ops
    for f in STAT_FIELDS:
        assert getattr(d_b, f) == getattr(d_r, f), (
            f"{name}: {f} diverges: batched={getattr(d_b, f)} "
            f"reference={getattr(d_r, f)}")


@pytest.mark.parametrize("model", sorted(MEMORY_MODELS))
@pytest.mark.parametrize("name", ["DurableMSQ", "UnlinkedQ", "OptUnlinkedQ",
                                  "OptLinkedQ"])
def test_batched_matches_reference_all_models(name, model):
    """Accounting parity holds on every memory model, not just Optane."""
    _, d_b = _run_sequential(name, model, n_ops=60)
    _, d_r = _run_sequential(name, model, nvram_cls=ReferenceNVRAM, n_ops=60)
    for f in STAT_FIELDS:
        assert getattr(d_b, f) == getattr(d_r, f), (
            f"{name}/{model}: {f}: batched={getattr(d_b, f)} "
            f"reference={getattr(d_r, f)}")


@pytest.mark.parametrize("name", DURABLE7)
def test_batched_multithread_results_sane(name):
    """run_batched at 8 threads: every dequeue result is FIFO-consistent
    (items are unique; the recovered drain matches what was not dequeued)
    and the paper's metrics keep their structure."""
    h = QueueHarness(ALL_QUEUES[name], nthreads=8, area_nodes=512)
    plans, prefill = make_plans("pairs", 8, 40)
    for i in range(prefill):
        h.queue.enqueue(0, ("pre", i))
    res = h.run_batched(plans)
    assert res.ops_completed == 8 * 40
    got = [r.item for r in res.ops
           if r.kind == "deq" and r.item is not None]
    assert len(got) == len(set(got)), "duplicate dequeue"
    enqueued = {r.item for r in res.ops if r.kind == "enq"}
    enqueued |= {("pre", i) for i in range(prefill)}
    assert set(got) <= enqueued, "invented item"
    if name in ("OptUnlinkedQ", "OptLinkedQ"):
        assert res.stats.post_flush_accesses == 0


def test_second_amendment_zero_post_flush_at_scale():
    """The paper's headline invariant survives three orders of magnitude
    more ops than the seed engine could run: 16 threads x 500 ops."""
    h = QueueHarness(ALL_QUEUES["OptUnlinkedQ"], nthreads=16,
                     area_nodes=2048)
    plans, prefill = make_plans("mixed5050", 16, 500)
    for i in range(prefill):
        h.queue.enqueue(0, ("pre", i))
    res = h.run_batched(plans)
    assert res.ops_completed == 16 * 500
    assert res.stats.post_flush_accesses == 0
    # one fence per completed update op, modulo allocator-area and
    # constructor fences (a handful per thread)
    assert res.stats.fences <= res.ops_completed + 3 * 16


@pytest.mark.parametrize("engine", [NVRAM, ReferenceNVRAM])
def test_write_after_movnti_same_address_coherent(engine):
    """Coherence regression: a regular store after an NT store to the same
    address must win (last store in program order), on both engines --
    the seed oracle used to let the stale pending NT value shadow it."""
    nv = engine(1)
    a = nv.alloc_region(8, "r")
    nv.movnti(a, 1)
    nv.write(a, 2)
    assert nv.read(a) == 2
    nv.flush(a)
    nv.fence()
    nv.crash(mode="min")
    assert nv.pread(a) == 2


@pytest.mark.slow
def test_batched_engine_order_of_magnitude_faster():
    """Acceptance: the batched path must be >= 10x faster per op than the
    exact per-primitive OS-thread scheduler (measured ~100x+; the margin
    here is deliberately loose to stay robust on loaded CI runners)."""
    name = "OptUnlinkedQ"
    # exact engine: seed-scale run
    h1 = QueueHarness(ALL_QUEUES[name], nthreads=4, area_nodes=512)
    plans1, _ = make_plans("mixed5050", 4, 15)
    t0 = time.perf_counter()
    r1 = h1.run_scheduled(plans1, seed=0)
    exact_per_op = (time.perf_counter() - t0) / max(r1.ops_completed, 1)
    # batched engine: 16 threads x 1000 ops
    h2 = QueueHarness(ALL_QUEUES[name], nthreads=16, area_nodes=2048)
    plans2, _ = make_plans("mixed5050", 16, 1000)
    t0 = time.perf_counter()
    r2 = h2.run_batched(plans2)
    batched_per_op = (time.perf_counter() - t0) / max(r2.ops_completed, 1)
    assert r2.ops_completed == 16 * 1000
    assert exact_per_op >= 10 * batched_per_op, (
        f"batched {batched_per_op * 1e6:.1f}us/op vs "
        f"exact {exact_per_op * 1e6:.1f}us/op")
