"""Docs cross-reference checks: links resolve, referenced symbols exist.

Keeps `docs/*.md` and the README honest as the code moves:

* every relative markdown link (``[text](path)`` and ``[text](path#anchor)``)
  must point at a file that exists in the repo;
* every backticked dotted reference to this package (``repro.x.y`` or
  ``repro.x.y.Symbol`` / ``:meth:`repro...```) must import, and a trailing
  attribute must exist on the imported module/class;
* every backticked repo path (``src/.../*.py``, ``tests/*.py``,
  ``benchmarks/*.py``, ``docs/*.md``) must exist;
* every `benchmarks/run.py` command line quoted in a doc names a real
  subcommand and real flags, every backticked ``--flag`` span is a flag
  some repo CLI actually defines, and the fleet CSV schema block in
  docs/fleet.md matches `benchmarks.run.FLEET_CSV_COLUMNS` exactly.

CI runs this as its docs step; it is also part of the tier-1 suite.
"""
import importlib
import re
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
DOC_FILES = sorted(REPO.glob("docs/*.md")) + [REPO / "README.md"]

LINK_RE = re.compile(r"\[[^\]]+\]\(([^)\s]+)\)")
CODE_RE = re.compile(r"`+([^`]+)`+")
PKG_RE = re.compile(r"^(repro(?:\.\w+)+)$")
PATH_RE = re.compile(r"^(?:src|tests|benchmarks|docs|examples)/[\w./\-]+$")


def test_docs_exist():
    """The documentation set the architecture satellite promises."""
    for rel in ("docs/architecture.md", "docs/queues.md",
                "docs/benchmarking.md", "docs/fleet.md",
                "docs/observability.md", "README.md"):
        assert (REPO / rel).is_file(), f"missing {rel}"


@pytest.mark.parametrize("doc", DOC_FILES, ids=lambda p: p.name)
def test_relative_links_resolve(doc):
    text = doc.read_text()
    for target in LINK_RE.findall(text):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        path = target.split("#", 1)[0]
        if not path:
            continue
        resolved = (doc.parent / path).resolve()
        if REPO not in resolved.parents and resolved != REPO:
            continue   # escapes the repo: a GitHub-site URL (CI badge), not a file
        assert resolved.exists(), (
            f"{doc.relative_to(REPO)}: broken link {target!r} "
            f"(resolved to {resolved})")


def _module_and_attrs(dotted):
    """Split 'repro.a.b.C.d' into the longest importable module + attrs."""
    parts = dotted.split(".")
    for cut in range(len(parts), 0, -1):
        modname = ".".join(parts[:cut])
        try:
            mod = importlib.import_module(modname)
        except ImportError:
            continue
        return mod, parts[cut:]
    return None, parts


@pytest.mark.parametrize("doc", DOC_FILES, ids=lambda p: p.name)
def test_code_spans_refer_to_real_things(doc):
    text = doc.read_text()
    for span in CODE_RE.findall(text):
        span = span.strip().rstrip("(),")
        # :meth:`repro...` / :class:`repro...` roles reduce to the dotted path
        span = re.sub(r"^:\w+:", "", span).strip("`")
        if PKG_RE.match(span):
            mod, attrs = _module_and_attrs(span)
            assert mod is not None, (
                f"{doc.relative_to(REPO)}: unimportable reference `{span}`")
            obj = mod
            for a in attrs:
                assert hasattr(obj, a), (
                    f"{doc.relative_to(REPO)}: `{span}`: "
                    f"{obj!r} has no attribute {a!r}")
                obj = getattr(obj, a)
        elif PATH_RE.match(span):
            assert (REPO / span).exists(), (
                f"{doc.relative_to(REPO)}: `{span}` names a missing path")


def test_readme_links_to_docs():
    """Satellite: the README must point readers at docs/."""
    text = (REPO / "README.md").read_text()
    for rel in ("docs/architecture.md", "docs/queues.md",
                "docs/benchmarking.md", "docs/fleet.md",
                "docs/observability.md"):
        assert rel in text, f"README does not link {rel}"


def test_docs_name_the_load_bearing_tests():
    """architecture.md must state the differential coupling rule and the
    calibration gate by naming their test files (which must exist)."""
    arch = (REPO / "docs" / "architecture.md").read_text()
    for rel in ("tests/test_engine_differential.py",
                "tests/test_contention_calibration.py"):
        assert rel in arch, f"architecture.md does not mention {rel}"
        assert (REPO / rel).is_file(), f"{rel} named in docs but missing"


def test_docs_name_the_columnar_record_engine():
    """Satellite: architecture.md documents the columnar op-record store
    by naming its load-bearing symbols (each verified importable by
    test_code_spans_refer_to_real_things) and its equivalence gates, and
    benchmarking.md states the flags behind the CI smoke thresholds."""
    arch = (REPO / "docs" / "architecture.md").read_text()
    for span in ("repro.core.records.RecordStore",
                 "repro.core.records.OpsView",
                 "repro.core.records.EventsView",
                 "repro.core.opsched.generate_columnar_runner",
                 "repro.crash.capture.Boundary.rec_snap",
                 'records="legacy"'):
        assert span in arch, f"architecture.md does not mention {span}"
    for rel in ("tests/test_columnar_equivalence.py",
                "tests/test_records_property.py"):
        assert rel in arch, f"architecture.md does not mention {rel}"
        assert (REPO / rel).is_file(), f"{rel} named in docs but missing"
    bench = (REPO / "docs" / "benchmarking.md").read_text()
    for flag in ("--max-us-per-op", "--differential", "--area-nodes"):
        assert flag in bench, f"benchmarking.md does not mention {flag}"


def test_docs_name_the_observability_layer():
    """Satellite: docs/observability.md pins the telemetry layer's
    load-bearing symbols (verified importable by
    test_code_spans_refer_to_real_things), the trajectory tool, and the
    non-interference gate; architecture.md links to it."""
    obs = (REPO / "docs" / "observability.md").read_text()
    for span in ("repro.obs.profiler.PhaseProfiler",
                 "repro.obs.Heartbeat",
                 "repro.obs.manifest.build_manifest",
                 "benchmarks/bench_history.py",
                 "benchmarks/history/BENCH_9.json"):
        assert span in obs, f"observability.md does not mention {span}"
    for rel in ("tests/test_obs_bit_identity.py",
                "tests/test_obs_manifest.py"):
        assert rel in obs, f"observability.md does not mention {rel}"
        assert (REPO / rel).is_file(), f"{rel} named in docs but missing"
    arch = (REPO / "docs" / "architecture.md").read_text()
    assert "observability.md" in arch, \
        "architecture.md does not link docs/observability.md"


def test_docs_name_the_burst_executor():
    """Satellite: architecture.md documents the vectorized burst
    executor by naming its load-bearing symbols (each verified
    importable by test_code_spans_refer_to_real_things) and its
    equivalence/property gates; benchmarking.md states the smoke's
    burst axis flags; observability.md names the burst phase group."""
    arch = (REPO / "docs" / "architecture.md").read_text()
    for span in ("repro.core.burst.predict_grants",
                 "repro.core.records.RecordStore.extend_staged",
                 "repro.fleet.lowering.encode_program",
                 "last_burst_stats"):
        assert span in arch, f"architecture.md does not mention {span}"
    for rel in ("tests/test_burst_equivalence.py",
                "tests/test_burst_property.py"):
        assert rel in arch, f"architecture.md does not mention {rel}"
        assert (REPO / rel).is_file(), f"{rel} named in docs but missing"
    bench = (REPO / "docs" / "benchmarking.md").read_text()
    for flag in ("--burst", "--burst-workload", "--burst-window",
                 "--min-speedup-burst"):
        assert flag in bench, f"benchmarking.md does not mention {flag}"
    obs = (REPO / "docs" / "observability.md").read_text()
    from repro.obs import (PH_BURST_APPLY, PH_BURST_PREDICT,
                           PH_BURST_REPLAY, PH_BURST_VERIFY)
    for phase in (PH_BURST_PREDICT, PH_BURST_VERIFY, PH_BURST_APPLY,
                  PH_BURST_REPLAY):
        assert phase in obs, (
            f"observability.md does not name the {phase!r} phase")


def test_docs_name_the_fleet_backends():
    """Satellite: docs/fleet.md carries the backend matrix (all four
    `--backend` values, with the kernel source file), and
    docs/observability.md names the Pallas phase constants exactly as
    `repro.fleet.jaxexec.PallasBackend` reports them."""
    fleet = (REPO / "docs" / "fleet.md").read_text()
    for span in ("numpy", "jax-opcode", "pallas",
                 "src/repro/kernels/fleet_step.py",
                 "repro.fleet.lowering.encode_program"):
        assert span in fleet, f"fleet.md does not mention {span}"
    obs = (REPO / "docs" / "observability.md").read_text()
    from repro.fleet.jaxexec import PallasBackend
    for phase in (PallasBackend.PHASE_COMPILED, PallasBackend.PHASE_INTERPRET):
        assert phase in obs, (
            f"observability.md does not name the {phase!r} phase")
    assert "_wall_us_per_op" in obs, (
        "observability.md must document backend-qualified headline cells")


ARGV0_RE = re.compile(r'argv\[0\] == "([\w-]+)"')
ADDARG_RE = re.compile(r'add_argument\(\s*"(--[\w-]+)"')
FLAG_TOKEN_RE = re.compile(r"(?<![=\w-])--[\w-]+")

# Every CLI whose flags the docs may quote: the benchmark driver, the
# crash-sweep/repro entry point it forwards to, the perf-trajectory
# gate (docs/observability.md quotes its fold/compare flags), and the
# dry-run artifact tools (merge + roofline table).
CLI_SOURCES = ("benchmarks/run.py", "src/repro/crash/__main__.py",
               "benchmarks/bench_history.py", "benchmarks/merge_results.py",
               "benchmarks/roofline.py")


def _known_cli():
    """(subcommands, flags) actually defined by the repo's CLIs."""
    subcommands, flags = set(), {"--help"}
    for rel in CLI_SOURCES:
        src = (REPO / rel).read_text()
        subcommands.update(ARGV0_RE.findall(src))
        flags.update(ADDARG_RE.findall(src))
    return subcommands, flags


def _doc_command_lines(text):
    """Command lines invoking benchmarks/run.py, continuations joined."""
    lines, buf = [], None
    for raw in text.splitlines():
        line = raw.strip()
        if buf is not None:
            buf += " " + line.rstrip("\\").strip()
            if not line.endswith("\\"):
                lines.append(buf)
                buf = None
            continue
        if "benchmarks/run.py" in line and (
                "python" in line or line.startswith("benchmarks/")):
            if line.endswith("\\"):
                buf = line.rstrip("\\").strip()
            else:
                lines.append(line)
    return lines


@pytest.mark.parametrize("doc", DOC_FILES, ids=lambda p: p.name)
def test_doc_cli_commands_are_real(doc):
    """Satellite: every `benchmarks/run.py` invocation a doc quotes names
    a subcommand the driver dispatches and flags some parser defines."""
    subcommands, flags = _known_cli()
    text = doc.read_text()
    for cmd in _doc_command_lines(text):
        tail = cmd.split("benchmarks/run.py", 1)[1].split("`", 1)[0].strip()
        tokens = tail.split()
        if tokens and not tokens[0].startswith("-"):
            assert tokens[0] in subcommands, (
                f"{doc.relative_to(REPO)}: quoted command {cmd!r} uses "
                f"unknown subcommand {tokens[0]!r} (known: "
                f"{sorted(subcommands)})")
        for flag in FLAG_TOKEN_RE.findall(tail):
            assert flag in flags, (
                f"{doc.relative_to(REPO)}: quoted command {cmd!r} uses "
                f"unknown flag {flag!r}")


@pytest.mark.parametrize("doc", DOC_FILES, ids=lambda p: p.name)
def test_doc_flag_spans_are_real(doc):
    """Every backticked span that *starts* with `--` must be a flag one of
    the repo CLIs defines (catches renamed/retired flags in prose)."""
    _, flags = _known_cli()
    text = doc.read_text()
    for span in CODE_RE.findall(text):
        span = span.strip()
        if not span.startswith("--"):
            continue
        flag = span.split()[0].split("=", 1)[0]
        assert flag in flags, (
            f"{doc.relative_to(REPO)}: `{span}` quotes unknown flag "
            f"{flag!r}")


def test_fleet_csv_schema_block_matches_code():
    """Satellite: the fleet CSV schema block in docs/fleet.md must equal
    `benchmarks.run.FLEET_CSV_COLUMNS` -- same names, same order."""
    from benchmarks.run import FLEET_CSV_COLUMNS
    text = (REPO / "docs" / "fleet.md").read_text()
    section = text.split("## Fleet CSV schema", 1)[1].split("\n## ", 1)[0]
    m = re.search(r"```\n(.*?)```", section, re.S)
    assert m, "docs/fleet.md: no fenced schema block under 'Fleet CSV schema'"
    documented = [t for t in re.split(r"[\s,]+", m.group(1)) if t]
    assert documented == list(FLEET_CSV_COLUMNS), (
        f"docs/fleet.md schema block {documented} != "
        f"benchmarks.run.FLEET_CSV_COLUMNS {list(FLEET_CSV_COLUMNS)}")


def test_queue_enumeration_single_source_of_truth():
    """Satellite: docs/queues.md defers to the code's queue registries.

    `repro.core.DURABLE_QUEUES` is the documented source of truth for the
    queue enumeration: queues.md must say so, its table must list exactly
    the `ALL_QUEUES` names (7 durable + the MSQ baseline), and no doc may
    claim a queue that the registries do not know.
    """
    from repro.core import ALL_QUEUES, DURABLE_QUEUES
    text = (REPO / "docs" / "queues.md").read_text()
    assert "DURABLE_QUEUES" in text, \
        "queues.md must name repro.core.DURABLE_QUEUES as source of truth"
    assert len(DURABLE_QUEUES) == 7 and len(ALL_QUEUES) == 8
    table_names = {m.group(1) for m in
                   re.finditer(r"^\|\s*(\w+)\s*\|\s*`", text, re.M)}
    assert table_names == set(ALL_QUEUES), (
        f"queues.md table lists {sorted(table_names)} but the registries "
        f"enumerate {sorted(ALL_QUEUES)}")
