"""Quickstart: the paper's durable queues on simulated NVRAM, end to end.

Runs OptUnlinkedQ (the headline algorithm) under a deterministic concurrent
schedule, injects a full-system crash, recovers, and prints the two metrics
the paper is about: blocking fences per operation and accesses to flushed
cache lines.

  PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import (ALL_QUEUES, QueueHarness,
                        check_durable_linearizability, split_at_crash)


def main() -> None:
    for name in ("DurableMSQ", "UnlinkedQ", "OptUnlinkedQ"):
        h = QueueHarness(ALL_QUEUES[name], nthreads=3, area_nodes=512)
        plans = []
        for t in range(3):
            plan = []
            for i in range(12):
                plan.append(("enq", (t, i)))
                if i % 2:
                    plan.append(("deq", None))
            plans.append(plan)
        res = h.run_scheduled(plans, seed=7, crash_at=400)
        pre_events, _ = split_at_crash(h.events)
        pre_ops = list(res.ops)
        h.crash_and_recover(mode="random", seed=1)
        recovered = h.queue.drain(0)
        ok, why = check_durable_linearizability(pre_ops, pre_events,
                                                recovered)
        s = res.stats
        ops = max(res.ops_completed, 1)
        print(f"{name:14s} crash@400 -> recovered {len(recovered):2d} items "
              f"(durably linearizable: {ok})")
        print(f"{'':14s} fences/op={s.fences / ops:.2f}  "
              f"post-flush-accesses/op={s.post_flush_accesses / ops:.2f}  "
              f"sim-throughput={ops / (res.sim_time_ns / 1e3):.2f} Mops/s")
    print("\nThe second amendment (OptUnlinkedQ): one fence per op AND zero"
          " post-flush accesses -- that is the whole paper.")


if __name__ == "__main__":
    main()
