"""Durable batched serving (deliverable (b), serving flavor).

Submits a burst of requests to the durable request queue (ONE fsync for the
burst -- the group-commit fence), serves them in batches through the KV-cache
decode path, durably commits responses (one fence per batch), then crashes
the queue object and proves recovery re-serves exactly the unserved ones.

  PYTHONPATH=src python examples/serve_batch.py
"""
import shutil

import numpy as np

from repro.configs import reduced_config
from repro.serving import DurableRequestQueue, ServeEngine

DIR = "/tmp/repro_serve_example"


def main() -> None:
    shutil.rmtree(DIR, ignore_errors=True)
    cfg = reduced_config("yi-6b")
    q = DurableRequestQueue(DIR)
    rng = np.random.RandomState(0)
    q.submit([{"id": f"r{i}", "prompt": rng.randint(0, cfg.vocab, (4,)).tolist()}
              for i in range(10)])
    print(f"submitted 10 requests ({q.req_wal.stats.fences} fence)")

    eng = ServeEngine(cfg, q, max_len=32)
    eng.serve_once(batch_size=4, max_new=6)
    print(f"served first batch of 4; responses durable "
          f"({q.resp_wal.stats.fences} fence)")

    q.close()   # crash
    q2 = DurableRequestQueue(DIR)
    pending = q2.recover()
    print(f"recovered: {pending} requests still pending (expected 6)")
    eng2 = ServeEngine(cfg, q2, max_len=32)
    n = eng2.run(batch_size=4, max_new=6)
    print(f"served remaining {n}; total responses: {len(q2.responses())}")
    ids = sorted(r["id"] for r in q2.responses())
    assert ids == sorted(f"r{i}" for i in range(10)), ids
    print("every request answered exactly once across the crash.")


if __name__ == "__main__":
    main()
