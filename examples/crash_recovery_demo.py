"""Crash/recovery torture demo: sweep crash points over a concurrent
workload for every durable queue and verify durable linearizability at each
(the paper's §7 correctness argument, executed).

  PYTHONPATH=src python examples/crash_recovery_demo.py
"""
import sys

sys.path.insert(0, "src")

from repro.core import (DURABLE_QUEUES, QueueHarness,
                        check_durable_linearizability, split_at_crash)


def main() -> None:
    plans = []
    for t in range(3):
        p = []
        for i in range(8):
            p.append(("enq", (t, i)))
            if i % 2:
                p.append(("deq", None))
        plans.append(p)

    for name in sorted(DURABLE_QUEUES):
        checked = 0
        for crash_at in range(10, 500, 35):
            for mode in ("min", "random", "max"):
                h = QueueHarness(DURABLE_QUEUES[name], nthreads=3,
                                 area_nodes=256)
                res = h.run_scheduled([list(p) for p in plans], seed=crash_at,
                                      crash_at=crash_at)
                pre, _ = split_at_crash(h.events)
                h.crash_and_recover(mode=mode, seed=crash_at)
                rec = h.queue.drain(0)
                ok, why = check_durable_linearizability(list(res.ops), pre,
                                                        rec)
                assert ok, f"{name} @{crash_at}/{mode}: {why}"
                checked += 1
        print(f"{name:14s} durably linearizable across {checked} "
              f"crash points x modes")


if __name__ == "__main__":
    main()
