"""Crash/recovery torture demo: sweep crash points over a concurrent
workload for every durable queue and verify durable linearizability at each
(the paper's §7 correctness argument, executed).

  PYTHONPATH=src python examples/crash_recovery_demo.py
  PYTHONPATH=src python examples/crash_recovery_demo.py --quick   # CI smoke
"""
import argparse

from repro.core import (DURABLE_QUEUES, QueueHarness,
                        check_durable_linearizability, split_at_crash)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--stride", type=int, default=35,
                    help="crash-point stride over steps 10..500 (default 35)")
    ap.add_argument("--quick", action="store_true",
                    help="reduced sweep for CI smoke (stride 140)")
    args = ap.parse_args()
    stride = 140 if args.quick else args.stride
    plans = []
    for t in range(3):
        p = []
        for i in range(8):
            p.append(("enq", (t, i)))
            if i % 2:
                p.append(("deq", None))
        plans.append(p)

    for name in sorted(DURABLE_QUEUES):
        checked = 0
        for crash_at in range(10, 500, stride):
            for mode in ("min", "random", "max"):
                h = QueueHarness(DURABLE_QUEUES[name], nthreads=3,
                                 area_nodes=256)
                res = h.run_scheduled([list(p) for p in plans], seed=crash_at,
                                      crash_at=crash_at)
                pre, _ = split_at_crash(h.events)
                h.crash_and_recover(mode=mode, seed=crash_at)
                rec = h.queue.drain(0)
                ok, why = check_durable_linearizability(list(res.ops), pre,
                                                        rec)
                assert ok, f"{name} @{crash_at}/{mode}: {why}"
                checked += 1
        print(f"{name:14s} durably linearizable across {checked} "
              f"crash points x modes")


if __name__ == "__main__":
    main()
