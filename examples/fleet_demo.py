"""Fleet executor demo: run thousands of independent queue instances as
one vectorized program, then prove a sample of them bit-identical to
independent per-instance ``run_batched`` runs (docs/fleet.md).

  PYTHONPATH=src python examples/fleet_demo.py
  PYTHONPATH=src python examples/fleet_demo.py --quick   # CI smoke
"""
import argparse

from repro.fleet import FleetConfig, check_instances, run_fleet


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--instances", type=int, default=20_000,
                    help="fleet size (default 20000)")
    ap.add_argument("--ops", type=int, default=96,
                    help="plan steps per instance (default 96)")
    ap.add_argument("--backend", default="auto",
                    choices=("auto", "numpy", "jax"))
    ap.add_argument("--quick", action="store_true",
                    help="reduced fleet for CI smoke (2000 x 48, numpy)")
    args = ap.parse_args()
    instances, ops, backend = args.instances, args.ops, args.backend
    if args.quick:
        instances, ops, backend = 2_000, 48, "numpy"

    for queue in ("DurableMSQ", "OptUnlinkedQ", "OptLinkedQ"):
        cfg = FleetConfig(queue=queue, model="optane-clwb",
                          instances=instances, ops=ops, backend=backend)
        res = run_fleet(cfg)
        agg = res.aggregate()
        checks = check_instances(res, sample=4)
        ok = sum(1 for c in checks if c["ok"])
        assert ok == len(checks), f"{queue}: fleet diverged from run_batched"
        print(f"{queue:14s} {instances} instances x {ops} ops on "
              f"{res.backend}: {res.ops_per_sec / 1e6:.2f} Mops/s wall, "
              f"{agg.time_ns / res.total_ops:.1f} sim-ns/op, "
              f"{agg.fences / res.total_ops:.2f} fences/op, "
              f"bails={res.bails}, checked {ok}/{len(checks)} bit-identical")


if __name__ == "__main__":
    main()
