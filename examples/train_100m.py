"""End-to-end driver: train a ~100M-parameter model with the durable data
pipeline + single-commit-barrier checkpointing (deliverable (b)).

The model is a width/depth-scaled yi-6b family member (~110M params).  On
this CPU container a step takes seconds; pass --steps to taste.  The run is
crash-restartable: re-invoking resumes from the last committed checkpoint
and replays exactly the unconsumed data shards.

  PYTHONPATH=src python examples/train_100m.py --steps 300
  PYTHONPATH=src python examples/train_100m.py --steps 300 --crash-at 50
  PYTHONPATH=src python examples/train_100m.py --steps 300   # resumes
"""
import argparse
import dataclasses

from repro.configs import get_config


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--crash-at", type=int, default=None)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq-len", type=int, default=128)
    args = ap.parse_args()

    base = get_config("yi-6b")
    cfg100 = dataclasses.replace(
        base, name="yi-100m", n_layers=10, d_model=512, n_heads=8,
        n_kv_heads=2, d_head=64, d_ff=1408, vocab=64000,
        param_dtype="float32", compute_dtype="float32")
    print(f"model: {cfg100.name}  params={cfg100.n_params() / 1e6:.1f}M")

    # plug the custom config into the driver via a tiny shim
    import repro.launch.train as t
    orig = t.reduced_config
    t.reduced_config = lambda _a: cfg100
    try:
        out = t.train("custom", steps=args.steps, batch=args.batch,
                      seq_len=args.seq_len, ckpt_dir=args.ckpt_dir,
                      ckpt_every=10, crash_at=args.crash_at, reduced=True)
    finally:
        t.reduced_config = orig
    print(f"loss: {out['losses'][0]:.3f} -> {out['losses'][-1]:.3f} over "
          f"{len(out['losses'])} steps")


if __name__ == "__main__":
    main()
